"""Minimal functional parameter system.

Modules declare their parameters as trees of :class:`ParamDef` (shape +
logical axis names + initializer).  From one declaration we derive:

  * ``init_params``  — materialized jnp arrays (PRNG-split per leaf),
  * ``param_pspecs`` — a same-structure tree of ``PartitionSpec`` via the
    logical-axis rules in :mod:`repro.sharding.rules`,
  * abstract ``jax.ShapeDtypeStruct`` trees for allocation-free lowering.

This replaces flax/haiku (not installed) with ~150 lines, and keeps sharding
declarations next to the parameter shapes — the same pattern MaxText uses via
``nn.with_logical_partitioning``.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class ParamDef(NamedTuple):
    shape: Tuple[int, ...]
    logical_axes: Tuple[Optional[str], ...]
    init: str = "normal"      # normal | zeros | ones | embed | small
    dtype: Any = jnp.float32  # storage dtype (weights usually bf16 at scale)
    scale: float = 1.0        # multiplier on the default fan-in scale


def _init_leaf(key: jax.Array, d: ParamDef) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    if d.init == "embed":
        return (jax.random.normal(key, d.shape, jnp.float32) * 0.02 * d.scale).astype(d.dtype)
    # fan-in scaled normal (matrices: rows; convs: k*k*cin; vectors: size)
    fan_in = (int(np.prod(d.shape[:-1])) if len(d.shape) >= 2
              else max(int(np.prod(d.shape)), 1))
    std = d.scale / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, d.shape, jnp.float32) * std).astype(d.dtype)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def init_params(key: jax.Array, defs: Any) -> Any:
    """Materialize a tree of ParamDefs into arrays (deterministic per-path)."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, len(leaves)) if leaves else []
    out = [_init_leaf(k, d) for k, d in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, out)


def abstract_params(defs: Any) -> Any:
    """ShapeDtypeStruct tree (for .lower() without allocation)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs, is_leaf=is_def
    )


def param_bytes(defs: Any) -> int:
    total = 0
    for d in jax.tree.leaves(defs, is_leaf=is_def):
        total += int(np.prod(d.shape)) * jnp.dtype(d.dtype).itemsize
    return total


def param_count(defs: Any) -> int:
    return sum(int(np.prod(d.shape)) for d in jax.tree.leaves(defs, is_leaf=is_def))


def map_defs(fn: Callable[[ParamDef], Any], defs: Any) -> Any:
    return jax.tree.map(fn, defs, is_leaf=is_def)
