"""Configuration dataclasses for the repro framework.

One unified ``ModelConfig`` describes every architecture family in the zoo
(dense / moe / ssm / hybrid / vlm / audio enc-dec).  Architecture configs in
``repro/configs/`` instantiate these with the exact published hyper-params.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple


# ---------------------------------------------------------------------------
# Input shapes (the four assigned shape cells).
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeCell:
    """One assigned (workload) input shape."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPE_CELLS: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", 4_096, 256, "train"),
    ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    ShapeCell("decode_32k", 32_768, 128, "decode"),
    ShapeCell("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPE_CELLS}


# ---------------------------------------------------------------------------
# Model configuration.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_d_ff: int
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # shared expert (dense path always applied), used by kimi-style MoE
    num_shared_experts: int = 0
    shared_d_ff: int = 0


@dataclass(frozen=True)
class SSMConfig:
    state_size: int = 64          # N (per-head state width)
    head_dim: int = 64            # P (channels per head)
    conv_width: int = 4
    chunk_size: int = 256         # SSD chunk length
    expand: int = 2               # d_inner = expand * d_model


@dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 4          # every k-th layer is an sLSTM block
    chunk_size: int = 256         # mLSTM chunkwise-parallel chunk
    proj_factor: float = 2.0      # mLSTM up-projection factor


@dataclass(frozen=True)
class VLMConfig:
    cross_attn_every: int = 5     # every k-th layer is a cross-attn layer
    num_image_tokens: int = 4_096 # stub patch-embedding count per sample


@dataclass(frozen=True)
class EncDecConfig:
    enc_layers: int = 24
    dec_layers: int = 24
    # stub audio frontend: precomputed frame embeddings of this length factor
    enc_seq_factor: float = 1.0   # enc_seq = factor * seq_len


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default d_model // num_heads
    qkv_bias: bool = False                  # qwen-style attention bias
    tie_embeddings: bool = False
    rope_theta: float = 500_000.0
    norm_eps: float = 1e-5
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    vlm: Optional[VLMConfig] = None
    encdec: Optional[EncDecConfig] = None
    # hybrid (zamba2): one shared attention block applied every k layers
    shared_attn_every: int = 0
    # distribution / numerics knobs
    dtype: str = "bfloat16"
    remat_policy: str = "minimal"            # none | minimal | full
    scan_layers: bool = True
    fsdp_over_pod: bool = False              # extend FSDP onto the pod axis
    parallelism: str = "2d"                  # "2d" = TP x FSDP (default),
                                             # "fsdp" = ZeRO-3 over all axes (no TP
                                             #          — right for ~1-10B archs),
                                             # "dp"   = fully replicated weights
                                             #          (right for <1B archs)
    pad_vocab_to_multiple: int = 0           # pad embedding/unembed rows so the
                                             # vocab axis shards over TP (Megatron-
                                             # style); 0 = no padding
    loss_chunk: int = 0                      # seq-chunked cross-entropy window
                                             # (0 = whole sequence at once)
    kv_cache_dtype: str = "bfloat16"         # "int8": quantized KV cache with
                                             # per-(token, head) scales — halves
                                             # the decode memory floor

    @property
    def dp_only(self) -> bool:
        return self.parallelism == "dp"

    @property
    def padded_vocab(self) -> int:
        m = self.pad_vocab_to_multiple
        if m <= 0:
            return self.vocab_size
        return ((self.vocab_size + m - 1) // m) * m
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # Rough parameter counts (used for MODEL_FLOPS = 6*N*D roofline math).
    def param_count(self) -> int:
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        qf = self.num_heads * hd
        kvf = self.num_kv_heads * hd
        attn = d * qf + 2 * d * kvf + qf * d
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family == "moe":
            m = self.moe
            expert = 3 * d * m.expert_d_ff
            shared = 3 * d * m.shared_d_ff * m.num_shared_experts
            router = d * m.num_experts
            per_layer = attn + m.num_experts * expert + shared + router + 2 * d
            return self.num_layers * per_layer + emb
        if self.family == "ssm":  # xlstm
            x = self.xlstm
            d_in = int(d * x.proj_factor)
            mlstm = 4 * d * d_in + d_in * d  # q,k,v,up(+gates) and down
            return self.num_layers * (mlstm + 2 * d) + emb
        if self.family == "hybrid":
            s = self.ssm
            d_in = s.expand * d
            mamba = 2 * d * d_in + d_in * d + d_in * (2 * s.state_size)
            n_attn = self.num_layers // max(self.shared_attn_every, 1)
            n_mamba = self.num_layers - n_attn
            shared_blk = attn + 3 * d * ff  # one shared param set
            return n_mamba * (mamba + 2 * d) + shared_blk + emb
        # dense / vlm / audio: swiglu mlp = 3*d*ff
        mlp = 3 * d * ff
        per_layer = attn + mlp + 2 * d
        n_layers = self.num_layers
        if self.family == "audio" and self.encdec is not None:
            n_layers = self.encdec.enc_layers + self.encdec.dec_layers
            per_layer += attn // 2  # decoder cross-attn (rough)
        if self.family == "vlm" and self.vlm is not None:
            pass  # cross-attn layers ~= self-attn layers in size; keep estimate
        return n_layers * per_layer + emb

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        m = self.moe
        hd = self.resolved_head_dim
        attn = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd + self.num_heads * hd * d
        expert = 3 * d * m.expert_d_ff
        shared = 3 * d * m.shared_d_ff * m.num_shared_experts
        per_layer = attn + m.top_k * expert + shared + d * m.num_experts + 2 * d
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return self.num_layers * per_layer + emb


# ---------------------------------------------------------------------------
# Run configuration (training / serving / dry-run).
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"   # bf16 for the giant archs
    compress_grads: bool = False    # int8 error-feedback DP all-reduce


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    opt: OptimizerConfig = field(default_factory=OptimizerConfig)
    microbatches: int = 1           # grad-accumulation steps per train_step
    seed: int = 0

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)


# Hardware constants for TPU v5e (roofline denominators).
@dataclass(frozen=True)
class HWConfig:
    peak_flops: float = 197e12       # bf16 FLOP/s per chip
    hbm_bw: float = 819e9            # bytes/s per chip
    ici_bw: float = 50e9             # bytes/s per link (~per-chip injection)
    hbm_bytes: float = 16e9          # HBM capacity per chip (v5e)


TPU_V5E = HWConfig()
