"""Quickstart: the DeepStream pipeline on one synthetic multi-camera slot.

    PYTHONPATH=src python examples/quickstart.py

Covers the paper's data plane end to end: synthetic co-located cameras ->
ROIDet (Pallas edge_motion kernel + connected components + light detector)
-> content features (a, c) -> utility prediction -> DP bandwidth allocation
-> codec simulation -> server detection F1.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import allocation as alloc
from repro.core.scheduler import DeepStreamSystem, SystemConfig
from repro.data.synthetic import MultiCameraScene, SceneConfig
from repro.train.detector_train import train_detector


def main() -> None:
    print("== DeepStream quickstart ==")
    print("training detectors (cached after first run)...")
    light = train_detector("light", steps=300, batch=12)
    server = train_detector("server", steps=600, batch=12)

    sysd = DeepStreamSystem(SystemConfig(), light, server)
    scene = MultiCameraScene(SceneConfig(seed=7))
    print("profiling utility function (paper section 5.1)...")
    info = sysd.profile(MultiCameraScene(SceneConfig(seed=42)), num_slots=3,
                        mlp_steps=300)
    print(f"  profiled: mlp_mse={info['mlp_mse']:.4f} "
          f"tau_wl={info['tau_wl']:.0f}Kbps tau_wh={info['tau_wh']:.0f}Kbps")

    seg = scene.segment()
    roi = sysd.camera_features(seg["frames"])
    a = np.asarray(roi.area_ratio)
    c = np.asarray(roi.confidence)
    print("\nROIDet content features per camera:")
    for i in range(len(a)):
        print(f"  cam{i}: ROI area ratio a={a[i]:.2f}, confidence c={c[i]:.2f}")

    W = 900.0  # Kbps available this slot
    util, best_res = alloc.build_utility_table(
        sysd.mlp, a, c, sysd.cfg.codec.bitrates_kbps,
        sysd.cfg.codec.resolutions, sysd.cfg.lam())
    al = alloc.allocate_dp(util, best_res, sysd.cfg.codec.bitrates_kbps, W)
    print(f"\nDP allocation under W={W:.0f}Kbps "
          f"(predicted utility {al.predicted_utility:.3f}):")
    f1s = []
    for i in range(len(a)):
        f1, size = sysd.encode_eval(seg["frames"][i], seg["boxes"][i],
                                    roi.mask[i], al.bitrates_kbps[i],
                                    al.resolutions[i])
        f1s.append(f1)
        print(f"  cam{i}: b={al.bitrates_kbps[i]:4.0f}Kbps "
              f"r={al.resolutions[i]:.2f} -> F1={f1:.3f} "
              f"({size/1024:.0f} KiB)")
    print(f"\nslot utility (sum of F1): {sum(f1s):.3f}")


if __name__ == "__main__":
    main()
