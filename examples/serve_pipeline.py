"""End-to-end driver (the paper's kind: serving/analytics).

    PYTHONPATH=src python examples/serve_pipeline.py

The full deployment shape in miniature: the DeepStream ingest tier streams
ROI-cropped segments from correlated cameras under a fluctuating bandwidth
trace (elastic transmission active), and the analytics tier serves a zoo
backbone (reduced qwen1.5-4b) with continuous-batched requests derived from
the per-camera detections ("describe what camera i saw").
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro.configs import smoke_config
from repro.core.scheduler import DeepStreamSystem, SystemConfig
from repro.data.synthetic import MultiCameraScene, SceneConfig, bandwidth_trace
from repro.models.model import LM
from repro.serve.engine import Request, ServeEngine
from repro.train.detector_train import train_detector


def main() -> None:
    print("== ingest tier: DeepStream streaming loop ==")
    light = train_detector("light", steps=300, batch=12)
    server = train_detector("server", steps=600, batch=12)
    sysd = DeepStreamSystem(SystemConfig(eval_frames=3), light, server)
    sysd.profile(MultiCameraScene(SceneConfig(seed=42)), num_slots=3,
                 mlp_steps=300)
    scene = MultiCameraScene(SceneConfig(seed=9))
    trace = bandwidth_trace("low", 5, seed=2)
    logs = sysd.run(scene, trace, method="deepstream")
    print(f"  {len(trace)} slots, mean utility {logs['utility'].mean():.3f}, "
          f"mean bytes/slot {logs['bytes'].mean()/1024:.0f} KiB, "
          f"elastic extra Kbps per slot: {np.round(logs['extra'], 1)}")

    print("\n== analytics tier: batched backbone serving ==")
    cfg = smoke_config("qwen1.5-4b")
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    eng = ServeEngine(lm, params, batch_slots=4, max_seq=64)
    rng = np.random.default_rng(0)
    # one request per camera per high-utility slot (token ids stand in for
    # the ROI-token stream a production frontend would emit)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 24,
                                               dtype=np.int32),
                    max_new_tokens=8)
            for i in range(8)]
    stats = eng.run(reqs)
    print(f"  served {stats['requests']} requests, {stats['tokens']} tokens "
          f"in {stats['steps']} engine steps "
          f"({stats['tok_per_s']:.1f} tok/s on this host)")
    print("\n(at pod scale the same prefill/decode functions lower onto the "
          "16x16 and 2x16x16 meshes — see repro.launch.dryrun)")


if __name__ == "__main__":
    main()
