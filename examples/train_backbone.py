"""Train a reduced zoo backbone for a few hundred steps on CPU.

    PYTHONPATH=src python examples/train_backbone.py [--arch granite-8b]

Exercises the full training stack: config -> LM (scanned layers, remat) ->
prefetching data pipeline -> microbatched AdamW train step -> watchdog ->
async atomic checkpoints -> restore-and-continue.
"""
import argparse
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()
    env = {"PYTHONPATH": str(REPO / "src")}
    import os
    env = {**os.environ, **env}
    ckpt = "/tmp/repro_example_ckpt"
    print(f"== phase 1: train {args.arch} (reduced) for {args.steps//2} steps ==")
    subprocess.run([sys.executable, "-m", "repro.launch.train",
                    "--arch", args.arch, "--smoke",
                    "--steps", str(args.steps // 2), "--batch", "8",
                    "--seq", "128", "--microbatches", "2",
                    "--ckpt-dir", ckpt, "--ckpt-every", "20"],
                   env=env, check=True)
    print("\n== phase 2: simulate restart — resume from latest checkpoint ==")
    subprocess.run([sys.executable, "-m", "repro.launch.train",
                    "--arch", args.arch, "--smoke",
                    "--steps", str(args.steps), "--batch", "8",
                    "--seq", "128", "--microbatches", "2",
                    "--ckpt-dir", ckpt, "--resume"],
                   env=env, check=True)


if __name__ == "__main__":
    main()
