"""Dry-run one cell on the production meshes and print its roofline.

    PYTHONPATH=src python examples/multi_pod_roofline.py \
        [--arch yi-34b] [--shape decode_32k]

Runs in a subprocess because the 512-device host-platform override must be
set before jax initializes.
"""
import argparse
import json
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-34b")
    ap.add_argument("--shape", default="decode_32k")
    args = ap.parse_args()
    import os
    env = {**os.environ, "PYTHONPATH": str(REPO / "src")}
    for mesh in ("single", "multi"):
        print(f"== {args.arch} x {args.shape} on the {mesh} mesh ==")
        subprocess.run([sys.executable, "-m", "repro.launch.dryrun",
                        "--arch", args.arch, "--shape", args.shape,
                        "--mesh", mesh], env=env, check=True)
        art = (REPO / "artifacts" / "dryrun" /
               f"{args.arch.replace('-', '_').replace('.', '_')}__{args.shape}__{mesh}.json")
        if art.exists():
            d = json.loads(art.read_text())
            if d["status"] == "ok":
                r = d["roofline"]
                print(f"  bottleneck={r['bottleneck']} "
                      f"step={r['roofline_step_s']*1e3:.1f}ms "
                      f"fraction={r['roofline_fraction']:.3f}\n")


if __name__ == "__main__":
    main()
