# Tier-1 tests + quick perf smoke — run `make ci` per PR so batched-path
# regressions (correctness or slot-step latency) are caught early.
# `ci-sharded` replays the tier-1 suite + the quick latency bench under 8
# fake XLA host devices, exercising the camera-mesh shard_map fleet paths.
# `ci-guard` is the transfer-guard lane: the device-resident control loop's
# timed slot loop runs under jax.transfer_guard_device_to_host("disallow")
# (apart from the scoped per-slot log harvest) on the 8-device mesh, and the
# D2H fetch counters prove zero per-slot control syncs on the CPU backend,
# where the guard itself is zero-copy-inert.
# `ci-episode` is the whole-trace lane: episode runs execute under
# jax.transfer_guard("disallow") in BOTH directions with NO scoped per-slot
# exemptions (the guard wraps the entire timed episode inside
# fleet_episode), on the 8-device mesh plus the 4-device subprocess
# harness; the fetch counters must show zero 'keep'/'control' and exactly
# TWO harvest fetches per run (the stacked F1/size pack + the stacked
# control pack, slot-count independent).
# `ci-scenarios` replays the scenario matrix (cross-mode differential
# harness, trace-length bucketing, golden logs) on the 8-device mesh in the
# harness's quick mode (reduced family set).
# `ci-faults` is the fault-tolerance lane: traced camera-churn/link-fault
# episodes (the dead==absent differential across all methods and runner
# modes), the checkify-guarded diagnostics runs (SystemConfig.checked on,
# invariant violations must raise), and the watchdog/supervisor recovery
# ladder.  Runs WITHOUT fake devices: the checked lane forces shard off.
# `ci-serve` is the continuous-serving lane: the windowed stream runner's
# kill-and-resume differential (SIGTERM + injected exception, all methods,
# zero recompiles after restore), the SLO watchdog ladder, checkpoint
# crash-atomicity, and the bounded-queue/drain-budget regressions.
# `ci-audit` is the STATIC lane (<2 min, nothing executes an episode): the
# traced-scope source lint, the jaxpr invariant audit (no host callbacks in
# timed scopes, slot-step donation, two-harvest episode outputs, fleet-size-
# independent PRNG fold-in, method x bucket executable count), and the full
# compiled-manifest golden check (signatures + static flops/bytes/peak
# memory vs tests/golden/executable_manifest.json).  Runs WITHOUT fake
# devices: the manifest pins single-device lowerings.
# `ci-chaos` is the seeded chaos lane: the deterministic fault-injection
# soak (ft/chaos.py) replays a scheduled storm — checkpoint bit-flips,
# truncation, torn manifests, save latency, source stalls/timeouts,
# mid-window exceptions, SIGTERM, duplicate/out-of-order/gap delivery and
# poisoned bandwidth records — against the windowed stream runner behind
# the hardened ingest path (serve/ingest.py).  Asserts the recovered logs
# match the fault-free run to <= 1e-5 for all four methods with ZERO
# episode recompiles after recovery, exact quarantine/gap-fill accounting,
# and that restore demonstrably falls back past corrupted newest
# generations to the newest valid one.  REPRO_CHAOS_HEADLINE_SLOTS=1000
# additionally enables the 1000-slot headline soak (retention-bounded
# checkpoint store + peak-RSS ceiling).  Runs WITHOUT fake devices, like
# ci-serve.
# `ci-pipeline` is the episode fast-path lane: the software-pipelined scan
# body's differential vs the straight-line reference body (all methods,
# with and without camera-churn faults, <= 1e-5), the zero-recompile /
# two-fetch harvest contracts on the pipelined path, the manifest
# cost_analysis dead-compute proofs (padded tail slots and the dropped
# reuse arm cost zero static flops), and the full kernel parity suite
# (edge_motion, flash_decode, knapsack_dp, tx_codec ops-vs-ref-vs-
# interpret).  Runs under 8 fake host devices like ci-episode.
# Lane pytest selections live ONCE, in tests/harness.py (LANES) — the lanes
# shell out to it instead of duplicating test lists here.
PY := PYTHONPATH=src python

.PHONY: test bench-quick ci ci-sharded ci-guard ci-episode ci-scenarios \
	ci-faults ci-serve ci-audit ci-chaos ci-pipeline

test:
	$(PY) -m pytest -q

bench-quick:
	$(PY) -m benchmarks.run --quick --only bench_allocation bench_latency

ci-sharded:
	REPRO_FAKE_DEVICES=8 $(PY) -m pytest -q
	REPRO_FAKE_DEVICES=8 $(PY) -m benchmarks.run --quick --only bench_latency

ci-guard:
	REPRO_FAKE_DEVICES=8 $(PY) -m pytest -q tests/test_control_device.py

ci-episode:
	REPRO_FAKE_DEVICES=8 $(PY) tests/harness.py --lane episode

ci-scenarios:
	REPRO_FAKE_DEVICES=8 REPRO_SCENARIO_QUICK=1 $(PY) tests/harness.py \
		--lane scenarios

ci-pipeline:
	REPRO_FAKE_DEVICES=8 $(PY) tests/harness.py --lane pipeline

ci-faults:
	$(PY) tests/harness.py --lane faults

ci-serve:
	$(PY) tests/harness.py --lane serve

ci-audit:
	$(PY) -m repro.analysis.lint
	$(PY) -m repro.analysis.jaxpr_audit --quiet
	REPRO_AUDIT_FULL=1 $(PY) tests/harness.py --lane audit

ci-chaos:
	REPRO_CHAOS_HEADLINE_SLOTS=1000 $(PY) tests/harness.py --lane chaos

ci: test bench-quick ci-sharded ci-guard ci-episode ci-scenarios ci-faults \
	ci-serve ci-audit ci-chaos ci-pipeline
