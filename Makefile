# Tier-1 tests + quick perf smoke — run `make ci` per PR so batched-path
# regressions (correctness or slot-step latency) are caught early.
# `ci-sharded` replays the tier-1 suite + the quick latency bench under 8
# fake XLA host devices, exercising the camera-mesh shard_map fleet paths.
# `ci-guard` is the transfer-guard lane: the device-resident control loop's
# timed slot loop runs under jax.transfer_guard_device_to_host("disallow")
# (apart from the scoped per-slot log harvest) on the 8-device mesh, and the
# D2H fetch counters prove zero per-slot control syncs on the CPU backend,
# where the guard itself is zero-copy-inert.
PY := PYTHONPATH=src python

.PHONY: test bench-quick ci ci-sharded ci-guard

test:
	$(PY) -m pytest -q

bench-quick:
	$(PY) -m benchmarks.run --quick --only bench_allocation bench_latency

ci-sharded:
	REPRO_FAKE_DEVICES=8 $(PY) -m pytest -q
	REPRO_FAKE_DEVICES=8 $(PY) -m benchmarks.run --quick --only bench_latency

ci-guard:
	REPRO_FAKE_DEVICES=8 $(PY) -m pytest -q tests/test_control_device.py

ci: test bench-quick ci-sharded ci-guard
