# Tier-1 tests + quick perf smoke — run `make ci` per PR so batched-path
# regressions (correctness or slot-step latency) are caught early.
PY := PYTHONPATH=src python

.PHONY: test bench-quick ci

test:
	$(PY) -m pytest -q

bench-quick:
	$(PY) -m benchmarks.run --quick --only bench_allocation bench_latency

ci: test bench-quick
