# Tier-1 tests + quick perf smoke — run `make ci` per PR so batched-path
# regressions (correctness or slot-step latency) are caught early.
# `ci-sharded` replays the tier-1 suite + the quick latency bench under 8
# fake XLA host devices, exercising the camera-mesh shard_map fleet paths.
PY := PYTHONPATH=src python

.PHONY: test bench-quick ci ci-sharded

test:
	$(PY) -m pytest -q

bench-quick:
	$(PY) -m benchmarks.run --quick --only bench_allocation bench_latency

ci-sharded:
	REPRO_FAKE_DEVICES=8 $(PY) -m pytest -q
	REPRO_FAKE_DEVICES=8 $(PY) -m benchmarks.run --quick --only bench_latency

ci: test bench-quick ci-sharded
